"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model, cache_specs, input_specs
from repro.models.api import text_len

ARCHS = list(list_configs())
BATCH, SEQ = 2, 64


def make_batch(cfg, rng, batch=BATCH, seq=SEQ, labels=True):
    st = text_len(cfg, seq)
    data = {"tokens": jax.random.randint(rng, (batch, st), 0,
                                         cfg.vocab_size, dtype=jnp.int32)}
    if labels:
        data["labels"] = jax.random.randint(rng, (batch, st), 0,
                                            cfg.vocab_size, dtype=jnp.int32)
    if cfg.frontend == "vision":
        data["frontend_embeds"] = jax.random.normal(
            rng, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        data["frontend_embeds"] = jax.random.normal(
            rng, (batch, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    return data


@pytest.fixture(scope="module")
def built():
    """Build + init each reduced arch once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.key(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, jax.random.key(1))
    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # gradient pytree matches params and is finite on a sample leaf
    leaves = jax.tree.leaves(grads)
    assert len(leaves) == len(jax.tree.leaves(params))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in leaves)
    assert np.isfinite(gnorm) and gnorm > 0.0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, jax.random.key(2), labels=False)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # decode: start from a fresh cache sized for SEQ + a few steps
    dec_cache = model.init_cache(BATCH, SEQ + 8)
    if cfg.encoder is not None:
        dec_cache["cross_kv"] = cache["cross_kv"]
    tok = jnp.full((BATCH, 1), 3, jnp.int32)
    for step in range(2):
        logits, dec_cache = model.decode_step(params, dec_cache, tok,
                                              jnp.asarray(step, jnp.int32))
        assert logits.shape == (BATCH, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, built):
    """Teacher-forced decode of a short sequence gives (approximately) the
    same final-position logits as prefill over the full sequence — the
    consistency invariant between the two code paths."""
    cfg, model, params = built(arch)
    if cfg.encoder is not None:
        pytest.skip("enc-dec positions are checked in test_whisper_paths")
    if cfg.moe is not None:
        # capacity drops differ between prefill chunks and single-token
        # decode; use a drop-free capacity factor for the consistency check
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
        model = build_model(cfg)
    seq = 8
    rng = jax.random.key(3)
    batch = make_batch(cfg, rng, seq=seq, labels=False)
    logits_pre, _ = model.prefill(params, batch)

    dec_cache = model.init_cache(BATCH, seq)
    toks = batch["tokens"]
    if cfg.frontend == "vision":
        pytest.skip("vision prefix offsets positions; covered by smoke test")
    logits = None
    for step in range(toks.shape[1]):
        logits, dec_cache = model.decode_step(
            params, dec_cache, toks[:, step:step + 1],
            jnp.asarray(step, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits_pre, np.float32),
        rtol=0.15, atol=0.35)


def test_input_specs_cover_all_cells():
    from repro.configs import SHAPES, applicable
    from repro.models import input_specs as specs_fn
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = applicable(cfg, shape)
            if not ok:
                continue
            specs = specs_fn(cfg, shape)
            assert "tokens" in specs or "cache" in specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
