"""int8 KV-cache decode: correctness vs the bf16 cache path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import transformer as tf


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "qwen2-0.5b"])
def test_int8_cache_matches_bf16(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    seq = 12
    toks = jax.random.randint(jax.random.key(1), (2, seq), 0,
                              cfg.vocab_size, dtype=jnp.int32)

    outs = {}
    for kv_int8 in (False, True):
        cache = tf.init_cache(cfg, 2, seq, kv_int8=kv_int8)
        logits = None
        for step in range(seq):
            logits, cache = model.decode_step(
                params, cache, toks[:, step:step + 1],
                jnp.asarray(step, jnp.int32))
        outs[kv_int8] = np.asarray(logits, np.float32)

    # int8 cache introduces bounded quantization error only
    denom = np.maximum(np.abs(outs[False]).max(), 1.0)
    rel = np.abs(outs[True] - outs[False]).max() / denom
    assert rel < 0.05, rel
    # top-1 predictions unchanged on a clear majority of positions
    agree = (outs[True].argmax(-1) == outs[False].argmax(-1)).mean()
    assert agree >= 0.5, agree


def test_int8_cache_half_the_bytes():
    cfg = get_config("minicpm-2b").reduced()
    c_bf16 = tf.init_cache(cfg, 2, 64)
    c_int8 = tf.init_cache(cfg, 2, 64, kv_int8=True)
    bytes_bf16 = sum(x.nbytes for x in jax.tree.leaves(c_bf16))
    bytes_int8 = sum(x.nbytes for x in jax.tree.leaves(c_int8))
    assert bytes_int8 < 0.6 * bytes_bf16
