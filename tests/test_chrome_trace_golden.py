"""Golden-file regression for the Chrome ``trace_event`` exporter.

Runs the SAME pinned configuration as tests/test_golden_trace.py with a
real tracer on the hook bus and compares the serialized Chrome JSON
byte-for-byte against a checked-in golden file.  This pins three things
at once:

* the exporter's output format (event fields, lane packing, metadata,
  µs rounding) — a rendering change shows up as a diff;
* determinism — the trace contains only simulated time, never wall-clock,
  so a seeded run serializes identically everywhere;
* non-perturbation — the run's commit trace must still match the
  ``cluster_sim_trace.txt`` golden while the tracer is attached, i.e.
  telemetry observes the simulation without changing it.

To regenerate after an *intentional* exporter/semantics change:

    PYTHONPATH=src python tests/test_chrome_trace_golden.py --regen

and commit the JSON diff alongside the change.
"""

import json
import os
import sys

from repro.core.harness import HookBus
from repro.obs import MetricsRegistry, Tracer, validate_chrome_trace

from test_golden_trace import GOLDEN_PATH as TXT_GOLDEN_PATH
from test_golden_trace import golden_run, render_trace

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "cluster_sim_chrome_trace.json")


def traced_golden_run():
    tracer = Tracer(process_name="mlfabric-sim")
    result = golden_run(HookBus(metrics=MetricsRegistry(), tracer=tracer))
    return result, tracer


def render_chrome(tracer: Tracer) -> str:
    # exactly Tracer.write_chrome's serialization
    return json.dumps(tracer.to_chrome(), indent=1, sort_keys=True) + "\n"


def test_chrome_trace_matches_golden():
    result, tracer = traced_golden_run()
    actual = render_chrome(tracer)
    with open(GOLDEN_PATH) as f:
        expected = f.read()
    assert actual == expected, (
        "Chrome trace export changed — if intentional, regenerate with "
        "`python tests/test_chrome_trace_golden.py --regen` and commit "
        "the JSON diff alongside the change")
    # attaching the tracer must not perturb the simulation itself
    with open(TXT_GOLDEN_PATH) as f:
        assert render_trace(result) == f.read()


def test_golden_chrome_trace_is_valid_and_complete():
    with open(GOLDEN_PATH) as f:
        chrome = json.load(f)
    assert validate_chrome_trace(chrome) == []
    cats = {e.get("cat") for e in chrome["traceEvents"]}
    # the pinned run exercises transfers, aggregation, commits, the
    # scheduler and scenario churn — all must appear in the export
    for needed in ("transfer", "aggregate", "commit", "scheduler",
                   "scenario"):
        assert needed in cats, f"golden trace lost its {needed} spans"


if __name__ == "__main__":
    if "--regen" in sys.argv:
        _, tracer = traced_golden_run()
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            f.write(render_chrome(tracer))
        print(f"wrote {GOLDEN_PATH}")
    else:
        print(__doc__)
