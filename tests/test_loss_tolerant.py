"""Hypothesis property suite for graceful degradation (DESIGN.md §12):
the ``ErrorFeedback`` compressor's accumulated residual norm stays within
the configured phase-aware bound across random drop patterns, drop rates
and top-k fractions — the twin of ``test_replica_property.py``, applied to
the data plane instead of replica divergence.

The bound is *enforced*, not assumed (an adversarial drop of the largest
top-k coordinate defeats any open-loop guarantee), so the invariant under
test is exactly the one the sender implements: after every ``compress``
call, ``||residual|| <= bound`` — and conservation: residual + everything
delivered reconstructs the quantize-rounded input stream.
"""

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAS_HYPOTHESIS = True
except ImportError:          # units below still run; properties skip
    HAS_HYPOTHESIS = False

    def given(*a, **k):
        return pytest.mark.skip(reason="property tests need hypothesis")

    def settings(*a, **k):
        return lambda f: f

from repro.dist.flatbuf import ErrorFeedback
from repro.dist.policy import PhaseLossCallback, PhaseLossPolicy

pytestmark = pytest.mark.lossy

DIM = 64

if HAS_HYPOTHESIS:
    _PROPERTY_ARGS = dict(
        seed=st.integers(0, 2 ** 31 - 1),
        keep=st.floats(0.05, 1.0),
        drop_rate=st.floats(0.0, 0.9),
        bound_frac=st.floats(0.05, 2.0),
        n_steps=st.integers(1, 10),
        data=st.data())
    _CONSERVATION_ARGS = dict(
        seed=st.integers(0, 2 ** 31 - 1),
        keep=st.floats(0.1, 1.0),
        drop_rate=st.floats(0.0, 0.8),
        n_steps=st.integers(1, 8))
else:
    _PROPERTY_ARGS = _CONSERVATION_ARGS = {}


@settings(max_examples=40, deadline=None)
@given(**_PROPERTY_ARGS)
def test_residual_never_exceeds_phase_bound(seed, keep, drop_rate,
                                            bound_frac, n_steps, data):
    """Across random drop patterns/rates/top-k fractions, the residual the
    sender carries into the next step never exceeds the bound the phase
    policy set for this step — including heavy-tailed gradients whose
    top-1 coordinate holds most of the mass."""
    rng = np.random.default_rng(seed)
    ef = ErrorFeedback(DIM)
    for step in range(n_steps):
        g = (rng.standard_normal(DIM)
             * rng.exponential(scale=2.0)).astype(np.float32)
        # occasionally spike one coordinate: the adversarial case where
        # dropping a single slot would defeat any open-loop bound
        if data.draw(st.booleans(), label=f"spike@{step}"):
            g[rng.integers(DIM)] *= 50.0
        bound = bound_frac * float(np.linalg.norm(g)) + 1e-6
        k = max(1, min(DIM, int(round(keep * DIM))))
        drop = data.draw(
            st.lists(st.booleans(), min_size=k, max_size=k),
            label=f"drops@{step}")
        drop = np.asarray(drop) | (rng.random(k) < drop_rate)
        chunk, delivered = ef.compress(g, keep=keep, bound=bound,
                                       drop_mask=drop)
        resid = float(np.linalg.norm(np.asarray(ef.residual)))
        assert resid <= bound * (1 + 1e-4), (
            resid, bound, keep, drop_rate, step, chunk.flushed)


@settings(max_examples=30, deadline=None)
@given(**_CONSERVATION_ARGS)
def test_delivered_plus_residual_conserves_mass(seed, keep, drop_rate,
                                                n_steps):
    """Nothing is silently lost: at any point, sum(delivered) + residual
    equals the sum of all inputs exactly (error feedback's defining
    telescoping identity; quantization error lives in the residual)."""
    rng = np.random.default_rng(seed)
    ef = ErrorFeedback(DIM)
    total_in = np.zeros(DIM, np.float64)
    total_out = np.zeros(DIM, np.float64)
    for _ in range(n_steps):
        g = rng.standard_normal(DIM).astype(np.float32)
        k = max(1, min(DIM, int(round(keep * DIM))))
        _, delivered = ef.compress(
            g, keep=keep, bound=float(np.linalg.norm(g)),
            drop_mask=rng.random(k) < drop_rate)
        total_in += g.astype(np.float64)
        total_out += np.asarray(delivered, np.float64)
    gap = total_in - (total_out + np.asarray(ef.residual, np.float64))
    assert np.abs(gap).max() <= 1e-3 * max(1.0, np.abs(total_in).max()), (
        np.abs(gap).max())


def test_no_bound_accepts_any_residual():
    ef = ErrorFeedback(DIM)
    g = np.zeros(DIM, np.float32)
    g[0] = 100.0
    chunk, _ = ef.compress(g, keep=1.0 / DIM,
                           drop_mask=np.asarray([True]))   # drop the top-1
    assert chunk.flushed == 0
    assert float(np.linalg.norm(np.asarray(ef.residual))) \
        == pytest.approx(100.0)


def test_bad_keep_rejected():
    ef = ErrorFeedback(DIM)
    with pytest.raises(ValueError):
        ef.compress(np.zeros(DIM, np.float32), keep=0.0)


# --------------------------------------------------------------------------- #
# the phase-aware policy driving the bounds
# --------------------------------------------------------------------------- #
class TestPhaseLossPolicy:
    def test_starts_permissive_and_tightens_when_flat(self):
        pol = PhaseLossPolicy(max_loss=0.3, min_loss=0.0, max_keep=1.0,
                              min_keep=0.1, ref_improvement=0.05)
        assert pol.phase() == 1.0                 # no data yet: early
        assert pol.allowed_loss() == pytest.approx(0.3)
        assert pol.topk_keep() == pytest.approx(0.1)
        for v in [10.0, 10.0, 10.0, 10.0]:        # flat loss curve
            pol.observe(v)
        assert pol.phase() == 0.0
        assert pol.allowed_loss() == pytest.approx(0.0)
        assert pol.topk_keep() == pytest.approx(1.0)

    def test_steep_descent_stays_permissive(self):
        pol = PhaseLossPolicy(ref_improvement=0.05)
        for v in [10.0, 8.0, 6.0, 4.0]:           # 20%/step improvement
            pol.observe(v)
        assert pol.phase() == 1.0

    def test_monotone_interpolation(self):
        pol = PhaseLossPolicy(max_loss=0.4, min_loss=0.1,
                              ref_improvement=0.1)
        losses, bounds = [], []
        curve = [10.0 * (0.9 ** i) for i in range(6)]       # decaying
        curve += [curve[-1]] * 10       # flat long enough to fill the window
        for v in curve:
            pol.observe(v)
            losses.append(pol.allowed_loss())
            bounds.append(pol.residual_bound(1.0))
        assert losses[-1] == pytest.approx(0.1)             # tightened
        assert min(losses) >= 0.1 and max(losses) <= 0.4
        assert bounds[-1] <= bounds[0]

    def test_validation(self):
        with pytest.raises(ValueError):
            PhaseLossPolicy(max_loss=1.0)
        with pytest.raises(ValueError):
            PhaseLossPolicy(min_keep=0.0)
        with pytest.raises(ValueError):
            PhaseLossPolicy(window=1)

    def test_callback_feeds_policy_from_batch_metrics(self):
        pol = PhaseLossPolicy()
        cb = PhaseLossCallback(pol, metric="loss")
        for step, v in enumerate([5.0, 5.0, 5.0]):
            cb.on_batch_end(None, step, {"loss": v, "other": 1.0})
        cb.on_batch_end(None, 99, None)           # metric-less: ignored
        cb.on_batch_end(None, 99, {"other": 2.0})
        assert pol.phase() == 0.0                 # saw the flat curve

    def test_transport_config_integration(self):
        """The simulator's bounded policy reads allowed_loss() live."""
        from repro.core.simulator import TransportConfig

        pol = PhaseLossPolicy(max_loss=0.3, min_loss=0.0)
        tc = TransportConfig(policy="bounded", phase_policy=pol)
        assert tc.allowed_loss() == pytest.approx(0.3)      # early
        for v in [1.0] * 5:
            pol.observe(v)                                  # flat
        assert tc.allowed_loss() == pytest.approx(0.0)
        assert tc.repair_fraction(0.2, 0.0) == pytest.approx(0.2)
