"""Golden-trace regression: a seeded ClusterSim run's exact commit sequence.

The simulator is the measurement instrument behind every timing table in
this repo — a refactor that shifts event ordering, reservation arithmetic or
scenario semantics by one event would silently invalidate the benchmarks.
This pins a seeded run (stragglers, N2 bandwidth churn, a dynamic-cluster
scenario, aggregation, tau_max drops all active) against a checked-in
trace: worker, version-used, version-committed, aggregated flag, and commit
time to 3 decimals, one line per commit.

To regenerate after an *intentional* semantics change:

    PYTHONPATH=src python tests/test_golden_trace.py --regen

and include the trace diff in the same commit as the semantics change.
"""

import os
import sys

from repro.core.network import gbps, mb
from repro.core.scenario import (AggregatorFail, Scenario, WorkerJoin,
                                 WorkerLeave, bandwidth_trace)
from repro.core.scheduler import SchedulerConfig
from repro.core.simulator import C2, ClusterSim, N2

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "cluster_sim_trace.txt")


def golden_run(hooks=None, transport=None, extra_events=()):
    """The pinned configuration: every simulator feature on one run.

    ``hooks`` (a ``repro.core.harness.HookBus``) attaches telemetry to the
    same pinned run — tests/test_chrome_trace_golden.py pins the Chrome
    trace export of this exact configuration, and the test below doubles
    as proof that an attached tracer cannot perturb the simulation.
    ``transport`` / ``extra_events`` let tests/test_transport.py prove the
    complementary invariant: a configured transport tier (and zero-rate
    loss events) cannot perturb it either."""
    scenario = Scenario(
        [WorkerLeave(time=2.0, worker="worker5"),
         AggregatorFail(time=2.5, host="worker0"),
         WorkerJoin(time=4.0)]
        + bandwidth_trace("worker2", [(1.0, gbps(1), gbps(1)),
                                      (3.0, gbps(10), gbps(10))])
        + list(extra_events))
    cfg = SchedulerConfig(server="server",
                          aggregators=["worker0", "worker1"],
                          tau_max=12, mode="async", batch_interval=0.1)
    # 100 MB updates over a 1.5 Gbps fabric keep aggregation groups in
    # flight long enough that the AggregatorFail re-routes one (reroutes,
    # drops, joins and leaves are all pinned non-trivially below)
    sim = ClusterSim(6, cfg, update_size=mb(100), compute_time=0.05,
                     straggler=C2, bandwidth=N2, monitor_lag=0.2, seed=42,
                     default_bw=gbps(1.5), scenario=scenario, hooks=hooks,
                     transport=transport)
    return sim.run(until_time=8.0)


def render_trace(result) -> str:
    lines = ["# worker version_used version_committed aggregated time"]
    for c in result.commits:
        lines.append(f"{c.worker} {c.version_used} {c.version_committed} "
                     f"{int(c.aggregated)} {c.time:.3f}")
    lines.append(f"# drops={result.drops} reroutes={result.reroutes} "
                 f"joins={result.joins} leaves={result.leaves}")
    return "\n".join(lines) + "\n"


def test_commit_sequence_matches_golden_trace():
    with open(GOLDEN_PATH) as f:
        expected = f.read()
    actual = render_trace(golden_run())
    assert actual == expected, (
        "simulator timing semantics changed — if intentional, regenerate "
        "with `python tests/test_golden_trace.py --regen` and commit the "
        "trace diff alongside the change")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            f.write(render_trace(golden_run()))
        print(f"wrote {GOLDEN_PATH}")
    else:
        print(__doc__)
