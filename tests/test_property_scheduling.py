"""Hypothesis property tests over the scheduling invariants (system-level)."""

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.aggregation import aggregate_updates
from repro.core.network import NetworkState
from repro.core.ordering import Update, order_updates
from repro.core.scheduler import MLfabricScheduler, SchedulerConfig


@st.composite
def cluster_and_updates(draw):
    n = draw(st.integers(2, 7))
    sizes = draw(st.lists(st.floats(10.0, 500.0), min_size=n, max_size=n))
    bws = draw(st.lists(st.sampled_from([10.0, 50.0, 100.0]),
                        min_size=n, max_size=n))
    versions = draw(st.lists(st.integers(-5, 0), min_size=n, max_size=n))
    t_avail = draw(st.lists(st.floats(0.0, 2.0), min_size=n, max_size=n))
    net = NetworkState([], default_bw=100.0)
    net.add_host("s", 100.0)
    net.add_host("a1", 100.0)
    ups = []
    for i in range(n):
        net.add_host(f"w{i}", bws[i])
        ups.append(Update(uid=i, worker=f"w{i}", size=sizes[i],
                          version=versions[i], norm=1.0, t_avail=t_avail[i]))
    return net, ups


@settings(max_examples=40, deadline=None)
@given(cluster_and_updates())
def test_ordering_partition_invariant(setup):
    """Every update is either committed or dropped — never lost."""
    net, ups = setup
    res = order_updates(list(ups), net, "s", tau_max=8, v_init=0)
    uids = sorted(u.uid for u in res.order) + sorted(u.uid
                                                     for u in res.dropped)
    assert sorted(uids) == sorted(u.uid for u in ups)


@settings(max_examples=40, deadline=None)
@given(cluster_and_updates())
def test_ordering_reservations_consistent(setup):
    """Committed transfers never start before their update is available
    and never end before they start."""
    net, ups = setup
    by_uid = {u.uid: u for u in ups}
    res = order_updates(list(ups), net, "s", tau_max=8, v_init=0)
    for uid, tr in res.transfers.items():
        assert tr.t_start >= by_uid[uid].t_avail - 1e-9
        assert tr.t_end >= tr.t_start - 1e-9


@settings(max_examples=30, deadline=None, derandomize=True)
@given(cluster_and_updates())
def test_aggregation_commit_monotone_and_complete(setup):
    """Aggregation commits every input, never later than the all-direct
    plan; composed with Alg. 2's order (the real pipeline) commit times are
    non-decreasing.  (For raw staggered arrivals monotonicity need not
    hold — work conservation lets an early update use a reservation gap.)"""
    net, ups = setup
    direct = aggregate_updates(ups, net.copy(), "s", [])
    agg = aggregate_updates(ups, net.copy(), "s", ["a1"])
    assert set(agg.commit_times) == {u.uid for u in ups}
    assert agg.makespan <= direct.makespan + 1e-9

    # Apply-order semantics: the server applies in Alg. 2's order even when
    # transfer completions interleave (a slow direct member's own uplink can
    # outlast a later group's aggregate — work conservation).  Within each
    # aggregation group, commits are monotone in the given order.
    ordering = order_updates(list(ups), net.copy(), "s")
    agg2 = aggregate_updates(ordering.order, net.copy(), "s", ["a1"])
    pos = {u.uid: i for i, u in enumerate(ordering.order)}
    for grp in agg2.groups:
        members = [u.uid for u in grp.members]
        assert members == sorted(members, key=pos.get)  # order preserved
        if grp.aggregator is not None and members:
            # an aggregated group commits atomically (one transfer)
            commits = {agg2.commit_times[m] for m in members}
            assert len(commits) == 1


@settings(max_examples=25, deadline=None)
@given(cluster_and_updates(), st.floats(0.1, 100.0))
def test_scheduler_divergence_always_bounded(setup, div_max):
    """End-to-end: whatever the topology/batch, the replication plan never
    exceeds the configured divergence bound."""
    net, ups = setup
    net.add_host("r", 100.0)
    cfg = SchedulerConfig(server="s", aggregators=["a1"], replica="r",
                          replica_aggregators=[], tau_max=8,
                          div_max=div_max, gamma=0.9, mode="async")
    sched = MLfabricScheduler(cfg)
    plan = sched.schedule_batch(list(ups), net)
    if plan.replication is not None:
        assert plan.replication.divergence_after <= div_max + 1e-6
