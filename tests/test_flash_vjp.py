"""Flash-attention custom VJP vs autodiff-through-plain-attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _plain_attention, blockwise_attention


def plain(q, k, v, causal):
    import math
    from repro.models.attention import _causal_bias
    scale = 1.0 / math.sqrt(q.shape[-1])
    mask = _causal_bias(q.shape[1], k.shape[1], 0, 0, causal)
    return _plain_attention(q, k, v, mask, scale)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,sq,h,kvh,d", [(2, 64, 4, 2, 16), (1, 128, 2, 2, 32)])
def test_forward_matches(causal, b, sq, h, kvh, d):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kvh, d), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(plain(q, k, v,
                                                                 causal)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal", [True, False])
def test_gradients_match(causal):
    """Custom flash backward == autodiff through plain attention."""
    ks = jax.random.split(jax.random.key(1), 3)
    b, sq, h, kvh, d = 1, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kvh, d), jnp.float32)

    def loss_flash(q, k, v):
        o = blockwise_attention(q, k, v, causal=causal, kv_block=16)
        return jnp.sum(jnp.sin(o))

    def loss_plain(q, k, v):
        return jnp.sum(jnp.sin(plain(q, k, v, causal)))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_plain = jax.grad(loss_plain, argnums=(0, 1, 2))(q, k, v)
    for gf, gp, name in zip(g_flash, g_plain, "qkv"):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gp),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"d{name} mismatch")


def test_gradients_bf16_path():
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.bfloat16)

    def loss(q):
        o = blockwise_attention(q, k, v, causal=True, kv_block=16)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(q)
    assert g.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
