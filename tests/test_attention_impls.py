"""Blockwise-jnp vs Pallas attention: the model-level impl switch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import build_model


@pytest.fixture(autouse=True)
def _restore_impl():
    yield
    attn.set_attention_impl("blockwise")


def test_blockwise_matches_plain():
    """Online-softmax scan == single-block plain attention."""
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    small = attn.blockwise_attention(q, k, v, causal=True, kv_block=32)
    big = attn.blockwise_attention(q, k, v, causal=True, kv_block=128)
    np.testing.assert_allclose(np.asarray(small), np.asarray(big),
                               rtol=2e-4, atol=2e-4)


def test_pallas_impl_matches_blockwise():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    ref = attn.blockwise_attention(q, k, v, causal=True, kv_block=32)
    attn.set_attention_impl("pallas")
    out = attn.blockwise_attention(q, k, v, causal=True, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_model_forward_same_under_both_impls():
    """A whole reduced model gives the same loss with either impl."""
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 64), 0,
                                     cfg.vocab_size, dtype=jnp.int32),
        "labels": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                     cfg.vocab_size, dtype=jnp.int32),
    }
    loss_ref, _ = model.loss_fn(params, batch)
    attn.set_attention_impl("pallas")
    loss_pl, _ = model.loss_fn(params, batch)
    assert abs(float(loss_ref) - float(loss_pl)) < 0.05
