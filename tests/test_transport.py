"""Bounded-loss transport tier (DESIGN.md §12).

Four layers of coverage:

* ``LossSchedule`` arithmetic — path composition, windowed events,
  byte-weighted ``transfer_loss`` over a real reservation profile;
* ``TransportConfig`` policy math — repair fractions per policy,
  phase-policy override;
* end-to-end ``ClusterSim`` behavior — reliable retransmission inflates
  commit time and counts retransmits, bounded mode accepts drops inside
  its allowance, deadlines/retry budgets give up and record drops;
* the zero-loss identity — with a transport tier *configured* but no
  loss (and separately with explicit zero-rate events), the pinned golden
  commit trace and the Chrome trace export are byte-identical to the
  transport-less goldens.
"""

import math

import pytest

from repro.core.network import LossSchedule, NetworkState, gbps, mb
from repro.core.scenario import LinkDegrade, PacketLoss, Scenario
from repro.core.scheduler import SchedulerConfig
from repro.core.simulator import ClusterSim, TransportConfig

from test_golden_trace import GOLDEN_PATH, golden_run, render_trace

pytestmark = pytest.mark.lossy


# --------------------------------------------------------------------------- #
# LossSchedule arithmetic
# --------------------------------------------------------------------------- #
class TestLossSchedule:
    def test_inactive_by_default_and_zero_rate_is_inert(self):
        ls = LossSchedule()
        assert not ls.active
        ls.set_drop("w1", 0.0, 0.0)            # zero rate, no window
        ls.set_corrupt("w1", 0.0, 0.0)
        assert not ls.active                   # golden safety: no state

    def test_path_composition(self):
        """src-up and dst-down losses compose as independent stages."""
        ls = LossSchedule()
        ls.set_drop("w1", 0.0, 0.2, direction="up")
        ls.set_drop("s", 0.0, 0.1, direction="down")
        drop, corrupt = ls.instant_loss("w1", "s", 1.0)
        assert drop == pytest.approx(1.0 - 0.8 * 0.9)
        assert corrupt == 0.0
        # reverse direction uses w1-down / s-up: neither is lossy
        assert ls.instant_loss("s", "w1", 1.0) == (0.0, 0.0)

    def test_until_window_expires(self):
        ls = LossSchedule()
        ls.set_drop("w1", 1.0, 0.5, until=2.0)
        assert ls.instant_loss("w1", "s", 1.5)[0] == pytest.approx(0.5)
        assert ls.instant_loss("w1", "s", 2.5)[0] == 0.0

    def test_transfer_loss_weights_by_bytes(self):
        """A loss window covering only part of a transfer charges only the
        bytes that moved inside the window."""
        net = NetworkState(["w1", "s"], default_bw=10.0)
        tr = net.reserve("w1", "s", 100.0, 0.0)     # 10 B/s -> [0, 10]
        ls = LossSchedule()
        ls.set_drop("w1", 0.0, 0.4, until=5.0)      # first half only
        dropped, corrupted = ls.transfer_loss("w1", "s", tr.profile)
        assert dropped == pytest.approx(0.2)        # 50 of 100 B at 40%
        assert corrupted == 0.0

    def test_corruption_charged_to_survivors(self):
        net = NetworkState(["w1", "s"], default_bw=10.0)
        tr = net.reserve("w1", "s", 100.0, 0.0)
        ls = LossSchedule()
        ls.set_drop("w1", 0.0, 0.5)
        ls.set_corrupt("w1", 0.0, 0.2)
        dropped, corrupted = ls.transfer_loss("w1", "s", tr.profile)
        assert dropped == pytest.approx(0.5)
        assert corrupted == pytest.approx(0.5 * 0.2)   # only non-dropped

    def test_remove_host_clears_links(self):
        ls = LossSchedule()
        ls.set_drop("w1", 0.0, 0.3)
        assert ls.active
        ls.remove_host("w1")
        assert not ls.active


# --------------------------------------------------------------------------- #
# TransportConfig policy math
# --------------------------------------------------------------------------- #
class TestTransportConfig:
    def test_policy_validated(self):
        with pytest.raises(ValueError):
            TransportConfig(policy="best-effort")

    def test_repair_fractions(self):
        assert TransportConfig(policy="lossless").repair_fraction(0.3, 0.1) \
            == 0.0
        assert TransportConfig(policy="reliable").repair_fraction(0.3, 0.1) \
            == pytest.approx(0.4)
        bounded = TransportConfig(policy="bounded", loss_tolerance=0.2)
        # drops above the allowance plus ALL corruption get repaired
        assert bounded.repair_fraction(0.3, 0.1) == pytest.approx(0.2)
        assert bounded.repair_fraction(0.1, 0.0) == 0.0

    def test_phase_policy_overrides_static_tolerance(self):
        class Tight:
            def allowed_loss(self):
                return 0.01

        tc = TransportConfig(policy="bounded", loss_tolerance=0.5,
                             phase_policy=Tight())
        assert tc.allowed_loss() == 0.01
        assert tc.repair_fraction(0.3, 0.0) == pytest.approx(0.29)


# --------------------------------------------------------------------------- #
# end-to-end simulator behavior
# --------------------------------------------------------------------------- #
def _run(transport, events, *, n=3, until=6.0, seed=11):
    cfg = SchedulerConfig(server="server", aggregators=[], tau_max=100,
                          mode="async", batch_interval=0.2)
    sim = ClusterSim(n, cfg, update_size=mb(50), compute_time=0.05,
                     seed=seed, default_bw=gbps(1.0),
                     scenario=Scenario(list(events)), transport=transport)
    return sim.run(until_time=until)


class TestSimulatorTransport:
    EVENTS = [PacketLoss(time=0.0, host="worker0", rate=0.4)]

    def test_reliable_retransmits_and_slows_commits(self):
        clean = _run(TransportConfig(policy="reliable"), [])
        lossy = _run(TransportConfig(policy="reliable"), self.EVENTS)
        assert lossy.retransmits > 0
        assert lossy.metrics.counter("transport/bytes_retransmitted").value > 0
        assert lossy.metrics.counter("transport/bytes_lost").value > 0
        # repairs consume uplink capacity -> strictly fewer commits
        assert lossy.n_commits < clean.n_commits
        assert lossy.drops == 0                     # nothing given up

    def test_lossless_policy_measures_but_never_repairs(self):
        res = _run(TransportConfig(policy="lossless"), self.EVENTS)
        assert res.retransmits == 0
        assert res.transport_loss_events > 0
        assert res.metrics.counter("transport/bytes_lost").value > 0

    def test_bounded_accepts_drops_inside_allowance(self):
        tc = TransportConfig(policy="bounded", loss_tolerance=0.5)
        res = _run(tc, self.EVENTS)
        assert res.retransmits == 0                 # 0.4 < 0.5: all accepted
        assert res.metrics.counter("transport/bytes_accepted").value > 0
        clean = _run(TransportConfig(policy="bounded", loss_tolerance=0.5), [])
        assert res.n_commits == clean.n_commits     # acceptance is free

    def test_bounded_repairs_corruption_even_inside_allowance(self):
        tc = TransportConfig(policy="bounded", loss_tolerance=0.9)
        res = _run(tc, [LinkDegrade(time=0.0, host="worker0",
                                    corrupt_rate=0.3)])
        assert res.retransmits > 0                  # corruption never accepted
        assert res.metrics.counter("transport/bytes_corrupted").value > 0

    def test_retry_budget_expiry_drops_update(self):
        tc = TransportConfig(policy="reliable", max_retries=1)
        res = _run(tc, [PacketLoss(time=0.0, host="worker0", rate=0.9)])
        assert res.transport_expired > 0
        # each drop stems from an expiry; an expiry whose give-up time
        # lands past the horizon never gets its drop event processed
        assert 0 < res.drops <= res.transport_expired
        # workers resume computing after a transport drop
        assert res.n_commits > 0

    def test_deadline_timeout_drops_update(self):
        tc = TransportConfig(policy="reliable", deadline=0.5,
                             backoff_base=1.0)
        res = _run(tc, [PacketLoss(time=0.0, host="worker0", rate=0.9)])
        assert res.transport_timeouts > 0
        assert 0 < res.drops <= res.transport_timeouts

    def test_loss_window_recovers(self):
        """After the ``until`` bound, transfers are clean again."""
        tc = TransportConfig(policy="reliable")
        res = _run(tc, [PacketLoss(time=0.0, host="worker0", rate=0.4,
                                   until=1.0)], until=8.0)
        clean = _run(tc, [], until=8.0)
        assert 0 < res.retransmits
        # losing the first second costs a bounded number of commits
        assert res.n_commits > clean.n_commits * 0.6


# --------------------------------------------------------------------------- #
# the zero-loss identity (the PR's non-perturbation guarantee)
# --------------------------------------------------------------------------- #
class TestZeroLossGoldenIdentity:
    def test_configured_transport_reproduces_text_golden(self):
        res = golden_run(transport=TransportConfig(policy="reliable"))
        with open(GOLDEN_PATH) as f:
            assert render_trace(res) == f.read(), (
                "a configured (but loss-free) transport tier must not "
                "perturb the pinned simulation")

    def test_zero_rate_events_reproduce_text_golden(self):
        events = [PacketLoss(time=1.0, host="worker1", rate=0.0),
                  LinkDegrade(time=1.5, host="worker3", corrupt_rate=0.0)]
        res = golden_run(transport=TransportConfig(policy="reliable"),
                         extra_events=events)
        with open(GOLDEN_PATH) as f:
            assert render_trace(res) == f.read(), (
                "zero-rate loss events must be completely inert")

    def test_configured_transport_reproduces_chrome_golden(self):
        from repro.core.harness import HookBus
        from repro.obs import MetricsRegistry, Tracer

        from test_chrome_trace_golden import GOLDEN_PATH as CHROME_GOLDEN
        from test_chrome_trace_golden import render_chrome

        tracer = Tracer(process_name="mlfabric-sim")
        golden_run(HookBus(metrics=MetricsRegistry(), tracer=tracer),
                   transport=TransportConfig(policy="reliable"))
        with open(CHROME_GOLDEN) as f:
            assert render_chrome(tracer) == f.read(), (
                "a loss-free transport tier must not add or move any "
                "trace span")
